"""Redistribution engine v2: plan cache correctness on the transport matrix.

3-D/4-D block <-> cyclic <-> block-cyclic(+overlap) round-trips with the
``arange_field`` oracle (every element encodes its own global index, so a
correct redistribution is simply "local values == global ids"), asserting
the plan-cached and cold paths move identical data across ThreadComm,
FileMPI, and SocketComm.
"""

import numpy as np
import pytest

import repro.core as pp
from repro.comm import run_spmd
from repro.comm.testing import TRANSPORTS, run_transport_spmd
from repro.core import Dmap, clear_plan_cache, plan_cache_stats
from repro.core.redist import build_plan, get_plan


def check_field(a):
    """An arange_field Dmat must hold exactly its global ids (owned part)."""
    own = a.local_view_owned()
    idx = [a.owned_indices(d) for d in range(a.ndim)]
    if not all(len(i) for i in idx):
        return
    grids = np.meshgrid(*idx, indexing="ij")
    lin = np.zeros_like(grids[0])
    for d, g in enumerate(grids):
        lin = lin * a.shape[d] + g
    np.testing.assert_array_equal(own, lin.astype(a.dtype))


def roundtrip_body(shape, spec_a, spec_b, use_cache):
    """Field under map A -> redistribute to B -> back to a fresh A-array;
    both hops must preserve the oracle."""
    import repro.comm as comm

    world = comm.Np()
    grid_a, dist_a, overlap_a = spec_a
    grid_b, dist_b, overlap_b = spec_b
    map_a = Dmap(grid_a, dist_a, range(world), overlap=overlap_a)
    map_b = Dmap(grid_b, dist_b, range(world), overlap=overlap_b)
    from repro.core.redist import redistribute

    x = pp.arange_field(*shape, map=map_a)
    z = pp.zeros(*shape, map=map_b)
    redistribute(z, x, use_cache=use_cache)
    check_field(z)
    back = pp.zeros(*shape, map=map_a)
    redistribute(back, z, use_cache=use_cache)
    check_field(back)
    return pp.agg(back, root=0)


SPECS_3D = [
    ([4, 1, 1], {}, None),
    ([1, 2, 2], ["c", "b", "c"], None),
    ([2, 2, 1], [{"dist": "bc", "size": 2}, "b", "b"], None),
    ([2, 2, 1], {}, [1, 0, 0]),  # block + overlap halo
]

SPECS_4D = [
    ([2, 2, 1, 1], {}, None),
    ([1, 1, 2, 2], ["b", "b", "c", "b"], None),
    ([1, 2, 1, 2], [{}, {"dist": "bc", "size": 3}, {}, "c"], None),
]


@pytest.mark.parametrize("transport", TRANSPORTS)
@pytest.mark.parametrize("src", range(len(SPECS_3D)))
@pytest.mark.parametrize("dst", range(len(SPECS_3D)))
def test_3d_roundtrip(transport, src, dst, tmp_path):
    shape = (9, 7, 10)
    args = (shape, SPECS_3D[src], SPECS_3D[dst], True)
    res = run_transport_spmd(roundtrip_body, 4, transport,
                             comm_dir=tmp_path, args=args)
    want = np.arange(np.prod(shape), dtype=float).reshape(shape)
    np.testing.assert_array_equal(res[0], want)


@pytest.mark.parametrize("transport", TRANSPORTS)
@pytest.mark.parametrize("src", range(len(SPECS_4D)))
@pytest.mark.parametrize("dst", range(len(SPECS_4D)))
def test_4d_roundtrip(transport, src, dst, tmp_path):
    shape = (4, 6, 5, 3)
    args = (shape, SPECS_4D[src], SPECS_4D[dst], True)
    res = run_transport_spmd(roundtrip_body, 4, transport,
                             comm_dir=tmp_path, args=args)
    want = np.arange(np.prod(shape), dtype=float).reshape(shape)
    np.testing.assert_array_equal(res[0], want)


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_cached_equals_cold(transport, tmp_path):
    """The memoized plan must move byte-identical data to a cold build."""
    shape = (11, 13, 6)
    spec_a = ([4, 1, 1], {}, None)
    spec_b = ([1, 2, 2], ["b", "c", {"dist": "bc", "size": 2}], None)
    outs = {}
    for use_cache in (False, True):
        args = (shape, spec_a, spec_b, use_cache)
        sub = tmp_path / f"cache{use_cache}"
        sub.mkdir()
        res = run_transport_spmd(roundtrip_body, 4, transport,
                                 comm_dir=sub, args=args)
        outs[use_cache] = res[0]
    np.testing.assert_array_equal(outs[False], outs[True])


def test_plan_cache_hits_and_stats():
    clear_plan_cache()

    def body():
        import repro.comm as comm

        world = comm.Np()
        src_map = Dmap([world, 1], {}, range(world))
        dst_map = Dmap([1, world], {}, range(world))
        x = pp.arange_field(12, 16, map=src_map)
        z = pp.zeros(12, 16, map=dst_map)
        for _ in range(10):
            z[:, :] = x
        return pp.agg(z, root=0)

    res = run_spmd(body, 4)
    np.testing.assert_array_equal(
        res[0], np.arange(12 * 16, dtype=float).reshape(12, 16)
    )
    stats = plan_cache_stats()
    # one miss per rank on the first turn, hits thereafter
    assert stats["misses"] == 4
    assert stats["hits"] == 36
    assert stats["hit_rate"] == pytest.approx(0.9)


def test_plan_is_reused_across_dmat_instances():
    """The plan keys on maps/shapes/region — not array identity."""
    m_src = Dmap([1, 1], {}, [0])
    m_dst = Dmap([1, 1], "c", [0])
    clear_plan_cache()
    p1 = get_plan(m_src, (6, 6), m_dst, (6, 6), ((0, 6), (0, 6)), 0)
    p2 = get_plan(m_src, (6, 6), m_dst, (6, 6), ((0, 6), (0, 6)), 0)
    assert p1 is p2
    assert plan_cache_stats()["hits"] >= 1
    # list-valued shapes/regions normalize to the same hashable key
    p3 = get_plan(m_src, [6, 6], m_dst, (6, 6), [(0, 6), (0, 6)], 0)
    assert p3 is p1


def test_shared_index_arrays_are_frozen():
    """The owned-index arrays are shared across every Dmat under one
    (map, shape, rank): in-place mutation must be rejected, not silently
    corrupt the siblings' index bookkeeping."""
    m = Dmap([1, 1], {}, [0])
    a = pp.arange_field(6, 6, map=m)
    with pytest.raises(ValueError):
        a.owned_indices(0)[0] = 99


def test_stable_tags_across_processes():
    """FileMPI ranks are separate processes: plan tags must not depend on
    the per-process hash salt.  build_plan twice must agree, and the tag
    must be a pure function of the key."""
    m_src = Dmap([2, 1], {}, [0, 1])
    m_dst = Dmap([1, 2], "c", [0, 1])
    a = build_plan(m_src, (8, 8), m_dst, (8, 8), ((0, 8), (0, 8)), 0)
    b = build_plan(m_src, (8, 8), m_dst, (8, 8), ((0, 8), (0, 8)), 1)
    assert a.tag == b.tag
    c = build_plan(m_src, (8, 9), m_dst, (8, 9), ((0, 8), (0, 9)), 0)
    assert c.tag != a.tag


class TestEmptyReductions:
    """Regression: zero-size arrays used to raise IndexError (vals[0])."""

    def test_sum_identity(self):
        m = Dmap([1, 1], {}, [0])
        e = pp.zeros(0, 4, map=m)
        assert e.sum() == 0.0

    def test_sum_identity_dtype(self):
        m = Dmap([1, 1], {}, [0])
        e = pp.zeros(0, 3, map=m, dtype=np.int64)
        s = e.sum()
        assert s == 0 and isinstance(s, np.int64)

    def test_max_min_raise_clear_error(self):
        m = Dmap([1, 1], {}, [0])
        e = pp.zeros(4, 0, map=m)
        with pytest.raises(ValueError, match="zero-size"):
            e.max()
        with pytest.raises(ValueError, match="zero-size"):
            e.min()

    def test_agg_sender_mutation_after_return(self):
        """Regression: agg() must pin its payload — a sender mutating its
        local part right after agg() returns must not corrupt the root."""
        import time

        def body():
            import repro.comm as comm

            m = Dmap([comm.Np(), 1], {}, range(comm.Np()))
            a = pp.arange_field(8, 4, map=m)
            if comm.Pid() == 0:
                time.sleep(0.05)  # let senders post and then mutate first
                return pp.agg(a)
            pp.agg(a)
            a.local[...] = -1.0
            return None

        for _ in range(5):
            res = run_spmd(body, 4)
            np.testing.assert_array_equal(
                res[0], np.arange(32.0).reshape(8, 4)
            )

    def test_spmd_empty_sum(self):
        def body():
            import repro.comm as comm

            m = Dmap([comm.Np(), 1], {}, range(comm.Np()))
            e = pp.zeros(0, 5, map=m)
            return e.sum()

        assert run_spmd(body, 3) == [0.0, 0.0, 0.0]
