"""Elastic fault-tolerant runtime: epoch fencing, liveness, deterministic
fault injection, checkpoint durability, and gang restart end-to-end.

Units cover each fence layer in isolation (rendezvous generations, socket
HELLO epochs + redial, shm arena staleness + re-attach, FileMPI epoch
tokens, the PPYTHON_FAULT grammar, torn-checkpoint discovery); the e2e
matrix kills a rank mid-run on every process transport and demands the
gang-restarted world finish bitwise-equal to an unfaulted run.
"""

import json
import socket as socket_mod
import threading
import time

import numpy as np
import pytest

from repro.comm import FileMPI, ShmComm, SocketComm, StragglerTimeout
from repro.comm.faultinject import (
    FaultPlan,
    instrument_faults,
    parse_fault,
    plan_from_env,
)
from repro.comm.liveness import straggler_message
from repro.comm.rendezvous import (
    _recv_rec,
    _send_rec,
    bind_listener,
    rendezvous_file,
    rendezvous_tcp,
    serve_endpoint_table,
    serve_generations,
)
from repro.comm.testing import shm_base_dir
from repro.obs import metrics
from repro.train.checkpoint import CheckpointManager, elastic_resume_step


def _threaded(np_, body, join=30):
    results = [None] * np_
    errors = [None] * np_

    def run(pid):
        try:
            results[pid] = body(pid)
        except BaseException as e:  # noqa: BLE001
            errors[pid] = e

    ts = [threading.Thread(target=run, args=(p,)) for p in range(np_)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(join)
    for e in errors:
        if e is not None:
            raise e
    return results


# ---------------------------------------------------------------------------
# fault injection: PPYTHON_FAULT grammar + deterministic plans
# ---------------------------------------------------------------------------


class TestFaultInject:
    def test_parse_multi_clause(self):
        specs = parse_fault(
            "kill:rank=2,after_sends=40;"
            "delay:rank=1,op=recv,ms=5,prob=0.1,seed=7;"
            "drop_once:rank=0,after_sends=3,count=2"
        )
        assert [s.action for s in specs] == ["kill", "delay", "drop_once"]
        assert specs[0].rank == 2 and specs[0].after_sends == 40
        assert specs[1].op == "recv" and specs[1].seed == 7
        assert specs[2].count == 2

    @pytest.mark.parametrize("junk", [
        "explode:rank=1",            # unknown action
        "kill:rank",                 # not key=value
        "kill:wat=3",                # unknown key
        "delay:op=sideways",         # bad op
        "kill:rank=one",             # non-integer
    ])
    def test_parse_rejects_junk_loudly(self, junk):
        with pytest.raises(ValueError):
            parse_fault(junk)

    def test_plan_filters_by_rank_and_epoch(self):
        specs = parse_fault("kill:rank=1;kill:rank=2,epoch=1")
        assert not FaultPlan(specs=specs, pid=0, epoch=0).armed
        assert FaultPlan(specs=specs, pid=1, epoch=0).armed
        # the epoch gate: rank 2's fault is armed only in generation 1,
        # so a restarted world (epoch 1) replays it and an epoch-0 world
        # never sees it — and vice versa for the default epoch-0 faults
        assert not FaultPlan(specs=specs, pid=2, epoch=0).armed
        assert FaultPlan(specs=specs, pid=2, epoch=1).armed
        assert not FaultPlan(specs=specs, pid=1, epoch=1).armed

    def test_kill_fires_on_counter_threshold(self):
        fired = []
        plan = FaultPlan(
            specs=parse_fault("kill:rank=0,after_sends=2"), pid=0,
            kill_fn=lambda: fired.append(plan.sends),
        )
        plan.before_send()
        plan.before_send()
        assert not fired  # sends 1 and 2 delivered
        plan.before_send()
        assert fired == [2]  # the 3rd send trips the armed kill

    def test_drop_once_eats_exactly_count_sends(self):
        plan = FaultPlan(
            specs=parse_fault("drop_once:rank=0,after_sends=1"), pid=0,
        )
        delivered = [plan.before_send() for _ in range(4)]
        assert delivered == [True, False, True, True]

    def test_seeded_delay_is_reproducible(self, monkeypatch):
        import repro.comm.faultinject as fi

        slept: list[float] = []
        monkeypatch.setattr(fi.time, "sleep",
                            lambda s: slept.append(s))

        def run_one():
            plan = FaultPlan(
                specs=parse_fault("delay:rank=0,op=recv,ms=3,prob=0.4,seed=9"),
                pid=0,
            )
            mark = len(slept)
            pattern = []
            for _ in range(32):
                plan.before_recv()
                pattern.append(len(slept) - mark)
            return pattern

        assert run_one() == run_one()  # same seed, same stall pattern

    def test_plan_from_env(self, monkeypatch):
        monkeypatch.delenv("PPYTHON_FAULT", raising=False)
        assert plan_from_env(0) is None
        monkeypatch.setenv("PPYTHON_FAULT", "kill:rank=1")
        assert plan_from_env(0) is None       # targets another rank
        assert plan_from_env(1) is not None
        assert plan_from_env(1, epoch=1) is None  # fault is epoch-0 only

    def test_instrument_wraps_send_and_is_idempotent(self, monkeypatch):
        monkeypatch.setenv("PPYTHON_FAULT", "drop_once:rank=0,after_sends=1")

        class Dummy:
            pid = 0
            np_ = 2

            def __init__(self):
                self.sent = []

            def send(self, dest, tag, obj):
                self.sent.append(obj)

            def isend(self, dest, tag, obj):
                self.send(dest, tag, obj)

            def recv(self, source, tag, timeout=None):
                return "msg"

        ctx = Dummy()
        assert instrument_faults(ctx) is ctx
        assert instrument_faults(ctx) is ctx  # idempotent
        for i in range(4):
            ctx.send(1, "t", i)
        assert ctx.sent == [0, 2, 3]  # the 2nd send vanished


# ---------------------------------------------------------------------------
# rendezvous generations: the bootstrap-time epoch fence
# ---------------------------------------------------------------------------


class TestRendezvousEpochFence:
    def test_serve_endpoint_table_drops_stale_generation(self):
        srv = bind_listener("127.0.0.1")
        port = srv.getsockname()[1]
        addr = f"127.0.0.1:{port}"
        holder = {}

        def serve():
            holder["table"] = serve_endpoint_table(
                srv, 2, time.monotonic() + 15, epoch=1
            )

        t = threading.Thread(target=serve, daemon=True)
        t.start()
        # a ghost of the dead generation registers first — the server
        # must close it without counting it toward the table
        ghost = socket_mod.create_connection(("127.0.0.1", port), timeout=5)
        _send_rec(ghost, (0, 0, ("ghost", 1)))
        live = _threaded(2, lambda pid: rendezvous_tcp(
            2, pid, ("127.0.0.1", 9200 + pid), addr,
            timeout=15, external_server=True, epoch=1,
        ))
        t.join(20)
        want = [("127.0.0.1", 9200), ("127.0.0.1", 9201)]
        assert holder["table"] == want
        assert all(tb == want for tb in live)
        ghost.settimeout(5)
        assert ghost.recv(64) == b""  # server hung up on the ghost
        ghost.close()

    def test_serve_generations_sequential_epochs_and_cache(self):
        srv = bind_listener("127.0.0.1")
        addr = f"127.0.0.1:{srv.getsockname()[1]}"
        t = threading.Thread(
            target=serve_generations, args=(srv, 2, time.monotonic() + 30),
            daemon=True,
        )
        t.start()

        def world(epoch):
            return _threaded(2, lambda pid: rendezvous_tcp(
                2, pid, ("127.0.0.1", 9300 + 10 * epoch + pid), addr,
                timeout=15, external_server=True, epoch=epoch,
            ))

        t0 = world(0)
        t1 = world(1)  # the relaunched generation, same listener
        assert t0[0] == [("127.0.0.1", 9300), ("127.0.0.1", 9301)]
        assert t1[0] == [("127.0.0.1", 9310), ("127.0.0.1", 9311)]
        # a completed generation is cached: a rank whose table read raced
        # a drop re-registers and is answered immediately
        again = rendezvous_tcp(2, 0, ("127.0.0.1", 9300), addr,
                               timeout=10, external_server=True, epoch=0)
        assert again == t0[0]
        srv.close()
        t.join(10)
        assert not t.is_alive()

    def test_serve_endpoint_table_drops_wrong_world_size(self):
        srv = bind_listener("127.0.0.1")
        port = srv.getsockname()[1]
        addr = f"127.0.0.1:{port}"
        holder = {}

        def serve():
            holder["table"] = serve_endpoint_table(
                srv, 2, time.monotonic() + 15, epoch=0
            )

        t = threading.Thread(target=serve, daemon=True)
        t.start()
        # a registrant claiming a 5-rank world must not join this 2-rank
        # table (elastic relaunches re-register under a bumped epoch; a
        # same-epoch size disagreement is always a bug to fence out)
        alien = socket_mod.create_connection(("127.0.0.1", port), timeout=5)
        _send_rec(alien, (0, 0, 5, ("alien", 1)))
        live = _threaded(2, lambda pid: rendezvous_tcp(
            2, pid, ("127.0.0.1", 9250 + pid), addr,
            timeout=15, external_server=True, epoch=0,
        ))
        t.join(20)
        want = [("127.0.0.1", 9250), ("127.0.0.1", 9251)]
        assert holder["table"] == want
        assert all(tb == want for tb in live)
        alien.settimeout(5)
        assert alien.recv(64) == b""  # hung up, not seated
        alien.close()

    def test_serve_generations_resizes_world_per_epoch(self):
        # the elastic_np flow: one listener serves epoch 0 at np=2 and
        # the relaunched epoch 1 at np=3 — each table sized from its own
        # registrants' world field, not the launcher's original np
        srv = bind_listener("127.0.0.1")
        addr = f"127.0.0.1:{srv.getsockname()[1]}"
        t = threading.Thread(
            target=serve_generations, args=(srv, 2, time.monotonic() + 30),
            daemon=True,
        )
        t.start()

        def world(epoch, np_):
            return _threaded(np_, lambda pid: rendezvous_tcp(
                np_, pid, ("127.0.0.1", 9350 + 10 * epoch + pid), addr,
                timeout=15, external_server=True, epoch=epoch,
            ))

        t0 = world(0, 2)
        t1 = world(1, 3)
        assert t0[0] == [("127.0.0.1", 9350), ("127.0.0.1", 9351)]
        assert t1[0] == [("127.0.0.1", 9360), ("127.0.0.1", 9361),
                         ("127.0.0.1", 9362)]
        srv.close()
        t.join(10)
        assert not t.is_alive()

    def test_serve_rendezvous_surfaces_bootstrap_errors(self):
        from repro.launch.prun import _serve_rendezvous

        addr, srv, errors = _serve_rendezvous(2, timeout=1.2)
        host, port = addr.rsplit(":", 1)
        # only rank 0 ever registers: the generation can never complete,
        # and the serve thread must record the timeout for the supervisor
        # to raise promptly instead of swallowing it
        s = socket_mod.create_connection((host, int(port)), timeout=5)
        _send_rec(s, (0, 0, ("127.0.0.1", 9400)))
        deadline = time.monotonic() + 10
        while not errors and time.monotonic() < deadline:
            time.sleep(0.05)
        s.close()
        assert errors, "serve thread swallowed its bootstrap failure"
        assert isinstance(errors[0], StragglerTimeout)
        assert "incomplete" in str(errors[0])

    def test_file_rendezvous_epoch_token_fences_stale_files(self, tmp_path):
        # a dead generation's endpoint file must not poison the relaunch
        (tmp_path / "ep_0").write_bytes(b"junk from a dead generation")
        tables = _threaded(2, lambda pid: rendezvous_file(
            2, pid, ("h", 9500 + pid), tmp_path, timeout=10, epoch=1,
        ))
        want = [("h", 9500), ("h", 9501)]
        assert all(tb == want for tb in tables)
        assert (tmp_path / "ep_0").exists()  # fenced out, not claimed


# ---------------------------------------------------------------------------
# socket transport: stale HELLOs, redial, epoch reset
# ---------------------------------------------------------------------------


def _socket_pair(epoch_a=0, epoch_b=0):
    la = bind_listener("127.0.0.1")
    lb = bind_listener("127.0.0.1")
    eps = [("127.0.0.1", la.getsockname()[1]),
           ("127.0.0.1", lb.getsockname()[1])]
    a = SocketComm(2, 0, eps, la, epoch=epoch_a)
    b = SocketComm(2, 1, eps, lb, epoch=epoch_b)
    return a, b


class TestSocketElastic:
    def test_stale_hello_is_refused(self):
        a, b = _socket_pair(epoch_a=0, epoch_b=1)
        before = metrics.counter("elastic.stale_hellos").value
        try:
            a.send(1, "t", np.arange(4.0))  # HELLO carries epoch 0
            deadline = time.monotonic() + 5
            while b._stale_hellos == 0 and time.monotonic() < deadline:
                time.sleep(0.02)
            assert b._stale_hellos >= 1
            assert metrics.counter("elastic.stale_hellos").value > before
            # the record behind the refused HELLO never matched
            assert b.pending_snapshot() == []
        finally:
            a.finalize()
            b.finalize()

    def test_send_redials_through_dead_connection(self):
        a, b = _socket_pair()
        before = metrics.counter("elastic.socket_redials").value
        try:
            a.send(1, "t", np.arange(3.0))
            np.testing.assert_array_equal(b.recv(0, "t"), np.arange(3.0))
            # sever the cached connection out from under the sender: the
            # next send must notice, redial, and re-send the record
            with a._peers_guard:
                a._peers[1].close()
            a.send(1, "t", np.arange(3.0) * 2)
            np.testing.assert_array_equal(b.recv(0, "t"), np.arange(3.0) * 2)
            assert metrics.counter("elastic.socket_redials").value > before
            assert a.dead_ranks() == []  # recovered: no longer dead
        finally:
            a.finalize()
            b.finalize()

    def test_send_reaches_restarted_peer_via_refresh_hook(self):
        a, b = _socket_pair()
        try:
            a.send(1, "t", np.float64(1.0))
            assert b.recv(0, "t") == 1.0
            b.finalize()  # rank 1 dies
            # ...and is relaunched as epoch 1 on a fresh ephemeral port
            lb2 = bind_listener("127.0.0.1")
            eps2 = [a.endpoints[0], ("127.0.0.1", lb2.getsockname()[1])]
            b2 = SocketComm(2, 1, eps2, lb2, epoch=1)
            try:
                a._refresh_endpoint = (
                    lambda d: eps2[1] if d == 1 else None
                )
                a.epoch_reset(1, epoch=1)  # survivor fences to epoch 1
                a.send(1, "t", np.float64(2.0))  # seq restarts at 0
                assert b2.recv(0, "t") == 2.0
            finally:
                b2.finalize()
        finally:
            a.finalize()

    def test_epoch_reset_clears_only_that_peers_streams(self):
        a, b = _socket_pair()
        try:
            a.send(1, "x", 1)
            a._recv_seq[(1, "x")] = 3
            a._send_seq[(0, "y")] = 5  # self-stream: another peer's state
            a.epoch_reset(1, epoch=2)
            assert a.epoch == 2
            assert not any(k[0] == 1 for k in a._send_seq)
            assert not any(k[0] == 1 for k in a._recv_seq)
            assert a._send_seq[(0, "y")] == 5
        finally:
            a.finalize()
            b.finalize()


# ---------------------------------------------------------------------------
# shm transport: heartbeat staleness + arena re-attach
# ---------------------------------------------------------------------------


class TestShmElastic:
    def _mk(self, tmpdir, pid, epoch=0, heartbeat=True):
        return ShmComm(
            2, pid, tmpdir, arena_bytes=65536, nonce="elastic-test",
            epoch=epoch, heartbeat=heartbeat, heartbeat_period=0.05,
        )

    def test_survivor_reattaches_to_restarted_peers_arena(self, tmp_path):
        import tempfile

        d = tempfile.mkdtemp(prefix="pp_elastic_", dir=shm_base_dir())
        a = self._mk(d, 0)
        b = self._mk(d, 1)
        before = metrics.counter("elastic.arena_reattach").value
        try:
            a.send(1, "t", np.arange(4.0))
            np.testing.assert_array_equal(b.recv(0, "t"), np.arange(4.0))
            b.finalize()  # the owner stops beating its inbound arenas
            time.sleep(0.35)  # > 4 * heartbeat_period: evidence of death
            assert a.dead_ranks() == [1]
            # the relaunched incarnation recreates its arenas (same
            # nonce, bumped epoch) — next send must detect the stale
            # mapping, re-attach, and restart the stream at seq 0
            b2 = self._mk(d, 1, epoch=1)
            try:
                a.send(1, "t2", np.arange(5.0))
                np.testing.assert_array_equal(
                    b2.recv(0, "t2"), np.arange(5.0)
                )
                assert (metrics.counter("elastic.arena_reattach").value
                        > before)
                assert a.dead_ranks() == []  # the new owner is beating
            finally:
                b2.finalize()
        finally:
            a.finalize()
            import shutil

            shutil.rmtree(d, ignore_errors=True)

    def test_paused_owner_is_not_falsely_reattached(self, tmp_path):
        """Staleness needs BOTH a dead heartbeat and a bumped epoch on
        disk — a merely slow owner (stale heartbeat, same epoch) must
        keep its arena and lose no messages."""
        import shutil
        import tempfile

        d = tempfile.mkdtemp(prefix="pp_elastic_", dir=shm_base_dir())
        a = self._mk(d, 0)
        b = self._mk(d, 1, heartbeat=False)  # "paused": never beats
        try:
            a.send(1, "t", np.float64(7.0))
            assert b.recv(0, "t") == 7.0
            time.sleep(0.35)  # heartbeat now stale from a's view
            arena_before = a._out[1]
            a.send(1, "t", np.float64(8.0))  # disk epoch unchanged: keep
            assert a._out[1] is arena_before
            assert b.recv(0, "t") == 8.0
        finally:
            a.finalize()
            b.finalize()
            shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------
# unified liveness diagnostics
# ---------------------------------------------------------------------------


class TestLivenessDiagnostics:
    def test_straggler_message_carries_dead_and_pending(self):
        class Diag:
            pid = 0

            def dead_ranks(self):
                return [2]

            def pending_snapshot(self, limit=8):
                return [(1, "grad", 0)]

        msg = straggler_message(
            Diag(), "'loss' (seq 3) from rank 1", "test-fabric",
            extra="; last wire error: boom",
        )
        assert "rank 0 timed out receiving 'loss' (seq 3) from rank 1" in msg
        assert "over test-fabric" in msg
        assert "stale-heartbeat ranks: [2]" in msg
        assert "pending unclaimed (src, tag, seq) matches: [(1, 'grad', 0)]" in msg
        assert msg.endswith("; last wire error: boom")
        assert metrics.gauge("liveness.dead_ranks").value == 1.0

    def test_straggler_message_survives_broken_diagnostics(self):
        class Broken:
            pid = 3

            def dead_ranks(self):
                raise RuntimeError("probe failed")

        msg = straggler_message(Broken(), "'x' from rank 0", "TCP")
        assert "stale-heartbeat ranks: []" in msg

    def test_filempi_pending_snapshot_lists_unclaimed_files(self, tmp_path):
        tx = FileMPI(2, 0, tmp_path, heartbeat=False)
        rx = FileMPI(2, 1, tmp_path, heartbeat=False)
        try:
            tx.send(1, "orphan", np.arange(3.0))
            snap = rx.pending_snapshot()
            assert snap and snap[0].startswith("m_s0_d1_")
            assert tx.pending_snapshot() == []
        finally:
            tx.finalize()
            rx.finalize()

    def test_filempi_epoch_token_separates_generations(self, tmp_path):
        tx = FileMPI(2, 0, tmp_path, heartbeat=False, epoch=1)
        rx0 = FileMPI(2, 1, tmp_path, heartbeat=False, epoch=0)
        rx1 = FileMPI(2, 1, tmp_path, heartbeat=False, epoch=1)
        try:
            tx.send(1, "t", np.float64(5.0))
            names = rx1.pending_snapshot()
            assert names and "E1_" in names[0]
            # the dead generation's receiver can never claim it
            with pytest.raises(StragglerTimeout):
                rx0.recv(0, "t", timeout=0.2)
            assert rx1.recv(0, "t") == 5.0
        finally:
            tx.finalize()
            rx0.finalize()
            rx1.finalize()


# ---------------------------------------------------------------------------
# checkpoint durability + elastic resume
# ---------------------------------------------------------------------------


def _tree(v):
    return {"x": np.arange(6.0) * v}


class TestCheckpointDurability:
    def _torn(self, tmp_path, breakage):
        mgr = CheckpointManager(tmp_path, keep=10)
        mgr.save(1, {"state": _tree(1.0)})
        mgr.save(2, {"state": _tree(2.0)})
        breakage(tmp_path / "step-00000002")
        return mgr

    def test_discovery_skips_torn_manifest(self, tmp_path):
        mgr = self._torn(
            tmp_path, lambda d: (d / "manifest.json").write_text("{ torn")
        )
        assert mgr.list_steps() == [1, 2]      # still visible...
        assert mgr.list_steps(valid_only=True) == [1]
        assert mgr.latest_step() == 1          # ...but never resumed from
        with pytest.raises(Exception):
            mgr.restore(step=2)  # explicit restore stays loud

    def test_discovery_skips_missing_segment(self, tmp_path):
        def rm_segment(d):
            with open(d / "manifest.json") as f:
                manifest = json.load(f)
            entries = next(iter(manifest["trees"].values()))
            seg = next(iter(entries.values()))["segments"][0]
            (d / seg["file"]).unlink()

        mgr = self._torn(tmp_path, rm_segment)
        assert mgr.latest_step() == 1

    def test_discovery_skips_size_mismatch(self, tmp_path):
        def truncate_segment(d):
            with open(d / "manifest.json") as f:
                manifest = json.load(f)
            entries = next(iter(manifest["trees"].values()))
            seg = next(iter(entries.values()))["segments"][0]
            with open(d / seg["file"], "ab") as f:
                f.write(b"\0" * 7)  # torn/corrupt shard: size disagrees

        mgr = self._torn(tmp_path, truncate_segment)
        assert "nbytes" in json.loads(
            (tmp_path / "step-00000002" / "manifest.json").read_text()
        )["trees"]["state"]["x"]["segments"][0]
        assert mgr.latest_step() == 1

    def test_save_fsyncs_shards_and_manifest(self, tmp_path, monkeypatch):
        import repro.train.checkpoint as ckpt

        synced = []
        real = ckpt.os.fsync
        monkeypatch.setattr(
            ckpt.os, "fsync", lambda fd: (synced.append(fd), real(fd))[1]
        )
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"state": _tree(1.0)})
        # at least one shard, the manifest, the step dir, and the parent
        assert len(synced) >= 4
        assert mgr.latest_step() == 1

    def test_elastic_resume_step(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        assert elastic_resume_step(mgr) is None
        mgr.save(3, {"state": _tree(1.0)})
        assert elastic_resume_step(mgr) == 3

        class FakeCtx:
            np_ = 2

            def __init__(self, peer):
                self.peer = peer

            def allgather(self, obj, tag=None):
                return [obj, self.peer]

        # the consistent recovery line is the min over all ranks
        assert elastic_resume_step(mgr, FakeCtx(5)) == 3
        assert elastic_resume_step(mgr, FakeCtx(1)) == 1
        # any rank with no valid checkpoint drags the world to scratch
        assert elastic_resume_step(mgr, FakeCtx(-1)) is None


# ---------------------------------------------------------------------------
# end to end: kill a rank mid-run on every transport, demand bitwise equality
# ---------------------------------------------------------------------------


def _expected_state(np_, steps=6):
    """The unfaulted ``elastic_allreduce`` result, replayed exactly."""
    state = np.zeros(8)
    for step in range(steps):
        for r in range(np_):
            state = state + (np.arange(8.0) + 1.0) * float(
                (r + 1) * (step + 1)
            )
    return state


class TestElasticEndToEnd:
    def test_unfaulted_baseline_matches_replay(self, tmp_path, monkeypatch):
        from repro.launch import pRUN

        monkeypatch.delenv("PPYTHON_FAULT", raising=False)
        res = pRUN(
            "repro.launch._selftest:elastic_allreduce", 2,
            transport="file", timeout=120,
            env={"PPYTHON_ELASTIC_CKPT": str(tmp_path)},
        )
        want = _expected_state(2).tolist()
        for state, epoch in res:
            assert state == want
            assert epoch == 0

    @pytest.mark.parametrize("transport,np_,kwargs", [
        ("file", 2, {}),
        ("socket", 2, {}),
        ("shm", 2, {}),
        ("hier", 4, {"nodes": 2}),  # shm within node pairs, TCP across
    ])
    def test_faulted_run_completes_bitwise_equal(
        self, transport, np_, kwargs, tmp_path, monkeypatch
    ):
        """Seeded rank-kill mid-run + ``restarts=1``: the gang restart
        resumes from the last common checkpoint and the final state is
        bitwise-equal to an unfaulted run's (deterministic replay)."""
        from repro.launch import pRUN

        monkeypatch.delenv("PPYTHON_FAULT", raising=False)
        restarts_before = metrics.counter("elastic.restarts").value
        res = pRUN(
            "repro.launch._selftest:elastic_allreduce", np_,
            transport=transport, restarts=1, timeout=180,
            env={
                "PPYTHON_ELASTIC_CKPT": str(tmp_path),
                "PPYTHON_FAULT": "kill:rank=1,after_sends=2",
            },
            **kwargs,
        )
        want = _expected_state(np_).tolist()
        for state, epoch in res:
            assert state == want
            assert epoch == 1  # every rank finished in the restarted world
        assert metrics.counter("elastic.restarts").value > restarts_before


# ---------------------------------------------------------------------------
# elastic resharding: gang restart at a *different* world size
# ---------------------------------------------------------------------------


def _expected_reshard_state(rows=13, cols=5, steps=6):
    """The ``elastic_reshard`` global state: each step adds the index
    field scaled by (step+1), independent of the grid it ran on."""
    base = (np.arange(float(rows))[:, None] * cols
            + np.arange(float(cols))[None, :] + 1.0)
    return base * sum(range(1, steps + 1))


class TestElasticReshard:
    @pytest.mark.parametrize("transport,src_np,dst_np", [
        ("file", 2, 3),    # scale up
        ("socket", 3, 2),  # scale down
    ])
    def test_restart_at_different_world_is_bitwise_equal(
        self, transport, src_np, dst_np, tmp_path, monkeypatch
    ):
        """Kill a rank mid-run; ``restarts=1, elastic_np=dst_np``
        relaunches the gang at a different size, the survivors resume
        through ``restore_resharded`` under the new world's map, and the
        final global state is bitwise-equal to an unfaulted fixed-size
        run (the state is defined purely by global index and step)."""
        from repro.launch import pRUN

        monkeypatch.delenv("PPYTHON_FAULT", raising=False)
        res = pRUN(
            "repro.launch._selftest:elastic_reshard", src_np,
            transport=transport, restarts=1, elastic_np=dst_np, timeout=180,
            env={
                "PPYTHON_ELASTIC_CKPT": str(tmp_path),
                "PPYTHON_FAULT": "kill:rank=1,after_sends=4",
            },
        )
        want = _expected_reshard_state().tolist()
        assert len(res) == dst_np  # results collected from the new world
        for state, epoch, world in res:
            assert epoch == 1 and world == dst_np
        assert res[0][0] == want  # rank 0 holds the aggregated state

    def test_elastic_np_requires_restarts_and_processes(self):
        from repro.launch import pRUN

        with pytest.raises(ValueError, match="elastic_np"):
            pRUN("repro.launch._selftest:pingpong", 2, elastic_np=3)
        with pytest.raises(ValueError, match="elastic_np"):
            pRUN("repro.launch._selftest:pingpong", 2, transport="thread",
                 restarts=1, elastic_np=3)
