"""SocketComm + rendezvous bootstrap: the shared-filesystem-free stack.

Transport-specific behavior (the generic send/recv/collective matrix
lives in test_comm_async/test_collectives/test_redist): both rendezvous
backends, ``SocketComm.bootstrap``, ``PPYTHON_TRANSPORT`` wiring in
``init()``/pRUN/slurm, call-time ``PPYTHON_RECV_TIMEOUT``, and the pRUN
scratch-dir lifecycle.
"""

import os
import threading

import numpy as np
import pytest

from repro.comm import SocketComm, StragglerTimeout, recv_timeout, set_context
from repro.comm.rendezvous import (
    advertised_host,
    bind_listener,
    exchange_endpoints,
    parse_addr,
    rendezvous_file,
    rendezvous_tcp,
)
from repro.comm.testing import run_transport_spmd


def _free_port() -> int:
    s = bind_listener("127.0.0.1")
    port = s.getsockname()[1]
    s.close()
    return port


def _threaded(np_, body):
    """Run ``body(pid)`` on np_ threads; rank-ordered results, first
    exception re-raised."""
    results = [None] * np_
    errors = [None] * np_

    def run(pid):
        try:
            results[pid] = body(pid)
        except BaseException as e:  # noqa: BLE001
            errors[pid] = e

    ts = [threading.Thread(target=run, args=(p,)) for p in range(np_)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(30)
    for e in errors:
        if e is not None:
            raise e
    return results


# ---------------------------------------------------------------------------
# rendezvous backends
# ---------------------------------------------------------------------------


class TestRendezvous:
    def test_parse_addr(self):
        assert parse_addr("node17:29400") == ("node17", 29400)
        with pytest.raises(ValueError):
            parse_addr("29400")

    def test_advertised_host_env_override(self, monkeypatch):
        monkeypatch.setenv("PPYTHON_HOST", "10.1.2.3")
        assert advertised_host() == "10.1.2.3"

    @pytest.mark.parametrize("np_", [2, 5])
    def test_tcp_rendezvous_all_ranks_get_same_table(self, np_):
        addr = f"127.0.0.1:{_free_port()}"
        tables = _threaded(
            np_,
            lambda pid: rendezvous_tcp(
                np_, pid, ("127.0.0.1", 9000 + pid), addr, timeout=20
            ),
        )
        want = [("127.0.0.1", 9000 + r) for r in range(np_)]
        assert all(t == want for t in tables)

    def test_tcp_rendezvous_times_out_on_missing_rank(self):
        addr = f"127.0.0.1:{_free_port()}"
        with pytest.raises(StragglerTimeout, match="rendezvous"):
            rendezvous_tcp(2, 0, ("127.0.0.1", 9000), addr, timeout=0.5)

    def test_tcp_rendezvous_survives_silent_stray_connection(self):
        """A connection that never registers (rank dying mid-dial, port
        scanner) must cost the server seconds, not the whole deadline —
        real ranks queued behind it still complete."""
        import socket as socket_mod
        import time

        port = _free_port()
        addr = f"127.0.0.1:{port}"
        results = {}

        def rank(pid):
            results[pid] = rendezvous_tcp(
                2, pid, ("127.0.0.1", 9100 + pid), addr, timeout=20
            )

        t0 = threading.Thread(target=rank, args=(0,))
        t0.start()
        time.sleep(0.3)  # let the server come up
        stray = socket_mod.socket()
        stray.connect(("127.0.0.1", port))  # HELLO never comes
        time.sleep(0.2)
        t1 = threading.Thread(target=rank, args=(1,))
        t1.start()
        t0.join(25)
        t1.join(25)
        stray.close()
        want = [("127.0.0.1", 9100), ("127.0.0.1", 9101)]
        assert results.get(0) == want and results.get(1) == want

    def test_file_rendezvous(self, tmp_path):
        tables = _threaded(
            3,
            lambda pid: rendezvous_file(
                3, pid, ("127.0.0.1", 7000 + pid), tmp_path, timeout=20
            ),
        )
        want = [("127.0.0.1", 7000 + r) for r in range(3)]
        assert all(t == want for t in tables)

    def test_file_rendezvous_dir_is_reusable(self, tmp_path):
        """Regression: leftover ep_* files must not serve a later run a
        stale endpoint table — the exchange reclaims its files once every
        rank has read the table."""
        for run in range(2):
            tables = _threaded(
                2,
                lambda pid: rendezvous_file(
                    2, pid, ("127.0.0.1", 7100 + 10 * run + pid),
                    tmp_path, timeout=20,
                ),
            )
            want = [("127.0.0.1", 7100 + 10 * run + r) for r in range(2)]
            assert all(t == want for t in tables), (run, tables)
        assert not list(tmp_path.iterdir())  # fully reclaimed

    def test_exchange_dispatch_prefers_tcp_addr(self, tmp_path, monkeypatch):
        # with both configured, the TCP server wins (the no-shared-FS path)
        addr = f"127.0.0.1:{_free_port()}"
        monkeypatch.setenv("PPYTHON_RDZV_ADDR", addr)
        monkeypatch.setenv("PPYTHON_RDZV_DIR", str(tmp_path))
        tables = _threaded(
            2,
            lambda pid: exchange_endpoints(
                2, pid, ("127.0.0.1", 8000 + pid), timeout=20
            ),
        )
        assert tables[0] == [("127.0.0.1", 8000), ("127.0.0.1", 8001)]
        assert not list(tmp_path.glob("ep_*"))  # file backend never touched

    def test_exchange_requires_some_rendezvous(self, monkeypatch):
        for var in ("PPYTHON_RDZV_ADDR", "PPYTHON_RDZV_DIR",
                    "PPYTHON_COMM_DIR"):
            monkeypatch.delenv(var, raising=False)
        with pytest.raises(ValueError, match="PPYTHON_RDZV_ADDR"):
            exchange_endpoints(2, 0, ("127.0.0.1", 1))


# ---------------------------------------------------------------------------
# bootstrap + init() wiring
# ---------------------------------------------------------------------------


class TestBootstrap:
    @pytest.mark.parametrize("mode", ["tcp", "file"])
    def test_bootstrap_then_message(self, mode, tmp_path, monkeypatch):
        monkeypatch.setenv("PPYTHON_HOST", "127.0.0.1")
        kw = (
            {"rdzv_addr": f"127.0.0.1:{_free_port()}"}
            if mode == "tcp"
            else {"rdzv_dir": tmp_path}
        )

        def body(pid):
            ctx = SocketComm.bootstrap(np_=3, pid=pid, timeout=20, **kw)
            set_context(ctx)
            try:
                from repro.comm import world_group

                out = world_group(ctx).allgather(pid * 11)
            finally:
                set_context(None)
                ctx.finalize()
            return out

        assert _threaded(3, body) == [[0, 11, 22]] * 3

    def test_init_selects_socket_transport(self, tmp_path, monkeypatch):
        """Real processes through init(): PPYTHON_TRANSPORT=socket + a
        rendezvous dir is all the env wiring a rank needs — and the
        rendezvous dir is only the bootstrap channel, never a message
        path (asserted: no .buf message files appear)."""
        import subprocess
        import sys

        code = (
            "import numpy as np, os, sys\n"
            "from repro.comm import init\n"
            "ctx = init()\n"
            "assert type(ctx).__name__ == 'SocketComm', type(ctx)\n"
            "if ctx.pid == 0:\n"
            "    ctx.send(1, 'x', np.arange(8))\n"
            "else:\n"
            "    s = int(ctx.recv(0, 'x', timeout=30).sum())\n"
            "    open(sys.argv[1], 'w').write(str(s))\n"
            "ctx.finalize()\n"
        )
        out = tmp_path / "result.txt"
        env = dict(
            os.environ,
            PPYTHON_TRANSPORT="socket",
            PPYTHON_NP="2",
            PPYTHON_RDZV_DIR=str(tmp_path / "rdzv"),
            PPYTHON_HOST="127.0.0.1",
        )
        procs = [
            subprocess.Popen(
                [sys.executable, "-c", code, str(out)],
                env=dict(env, PPYTHON_PID=str(pid)),
            )
            for pid in range(2)
        ]
        assert [p.wait(timeout=60) for p in procs] == [0, 0]
        assert out.read_text() == "28"
        assert not list((tmp_path / "rdzv").glob("*.buf"))

    def test_init_single_rank_is_localcomm(self, monkeypatch):
        from repro.comm import context as ctx_mod

        monkeypatch.setenv("PPYTHON_TRANSPORT", "socket")
        monkeypatch.setenv("PPYTHON_NP", "1")
        assert ctx_mod.init().np_ == 1

    def test_init_rejects_thread_transport_and_unknown(self, monkeypatch):
        from repro.comm import context as ctx_mod

        monkeypatch.setenv("PPYTHON_NP", "2")
        monkeypatch.setenv("PPYTHON_PID", "0")
        monkeypatch.setenv("PPYTHON_TRANSPORT", "thread")
        with pytest.raises(ValueError, match="run_spmd"):
            ctx_mod.init()
        monkeypatch.setenv("PPYTHON_TRANSPORT", "carrier-pigeon")
        with pytest.raises(ValueError, match="carrier-pigeon"):
            ctx_mod.init()

    def test_run_transport_spmd_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown transport"):
            run_transport_spmd(lambda: None, 2, "smoke-signals")


# ---------------------------------------------------------------------------
# satellite: PPYTHON_RECV_TIMEOUT read at call time
# ---------------------------------------------------------------------------


class TestRecvTimeoutKnob:
    def test_env_read_at_call_time(self, monkeypatch):
        monkeypatch.delenv("PPYTHON_RECV_TIMEOUT", raising=False)
        assert recv_timeout() == 300.0
        monkeypatch.setenv("PPYTHON_RECV_TIMEOUT", "0.25")
        assert recv_timeout() == 0.25  # no re-import needed

    def test_default_recv_deadline_follows_env(self, monkeypatch):
        """A default-timeout recv must honor a per-run override — the old
        import-time constant ignored it."""
        import time

        monkeypatch.setenv("PPYTHON_RECV_TIMEOUT", "0.2")

        def body():
            from repro.comm import get_context

            ctx = get_context()
            if ctx.pid == 1:
                t0 = time.monotonic()
                with pytest.raises(StragglerTimeout):
                    ctx.recv(0, "never")  # default timeout ← env
                return time.monotonic() - t0
            return 0.0

        took = run_transport_spmd(body, 2, "socket")[1]
        assert took < 5.0  # 300 s default would blow the test budget


# ---------------------------------------------------------------------------
# satellite: pRUN scratch-dir lifecycle + transport plumbing
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestPRunTransports:
    def test_socket_processes_end_to_end(self):
        from repro.launch import pRUN

        res = pRUN("repro.launch._selftest:pingpong", 2, transport="socket",
                   timeout=120)
        assert res[0] == np.arange(1000.0).sum() * 2

    def test_thread_transport_runs_in_process(self):
        from repro.launch import pRUN

        res = pRUN("repro.launch._selftest:bcast_barrier", 3,
                   transport="thread")
        assert res == [7.0 * 64] * 3

    def test_thread_transport_rejects_scripts(self, tmp_path):
        from repro.launch import pRUN

        script = tmp_path / "s.py"
        script.write_text("print('hi')\n")
        with pytest.raises(ValueError, match="module:function"):
            pRUN(str(script), 2, transport="thread")

    def test_socket_gang_restart_completes(self):
        """restarts= now works on the socket transport: rank 1 dies in
        epoch 0, the launcher gang-restarts the world under epoch 1 (the
        multi-generation rendezvous re-serves fresh endpoints), and the
        relaunched pingpong completes."""
        from repro.launch import pRUN

        res = pRUN("repro.launch._selftest:crash_once_pingpong", 2,
                   transport="socket", restarts=1, timeout=120)
        assert res[0] == np.arange(1000.0).sum() * 2

    def test_scratch_dir_removed_on_success_kept_on_failure(self, capsys):
        import glob
        import shutil
        import tempfile

        from repro.launch import pRUN

        tmp = tempfile.gettempdir()  # mkdtemp honors TMPDIR; so must we
        before = set(glob.glob(os.path.join(tmp, "ppython_*")))
        res = pRUN("repro.launch._selftest:pingpong", 2, timeout=120)
        assert res[0] == np.arange(1000.0).sum() * 2
        assert set(glob.glob(os.path.join(tmp, "ppython_*"))) == before

        try:
            with pytest.raises(RuntimeError, match="exited with code"):
                pRUN("repro.launch._selftest:does_not_exist", 2, timeout=120)
            leaked = set(glob.glob(os.path.join(tmp, "ppython_*"))) - before
            assert len(leaked) == 1  # kept for post-mortem, and said so
            assert "post-mortem" in capsys.readouterr().err
        finally:
            for d in set(glob.glob(os.path.join(tmp, "ppython_*"))) - before:
                shutil.rmtree(d, ignore_errors=True)


class TestSlurmSocketTemplate:
    def test_socket_script_has_rendezvous_no_comm_dir(self):
        from repro.launch.slurm import slurm_script

        txt = slurm_script("repro.launch._selftest:pingpong", 64,
                           transport="socket", nodes=4, rdzv_port=29777)
        assert "PPYTHON_TRANSPORT=socket" in txt
        assert "scontrol show hostnames" in txt
        assert ":29777" in txt
        assert "PPYTHON_COMM_DIR" not in txt  # no shared FS anywhere
        assert "PPYTHON_PID=\\$SLURM_PROCID" in txt

    def test_file_script_still_needs_comm_dir(self):
        from repro.launch.slurm import slurm_script

        txt = slurm_script("x:y", 4, "/shared/comm")
        assert "PPYTHON_COMM_DIR=/shared/comm" in txt
        with pytest.raises(ValueError, match="shared filesystem"):
            slurm_script("x:y", 4, transport="file")
