"""PITFALLS index algebra: unit + property tests against explicit indices."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pitfalls import (
    FALLS,
    block_cyclic_falls,
    block_falls,
    cyclic_falls,
    dist_falls,
    falls_indices,
    falls_intersect,
    falls_list_indices,
    falls_list_intersect,
    falls_list_size,
    intersect_ranks,
)


def explicit(f):
    return set(falls_indices(f).tolist())


class TestFALLS:
    def test_indices_basic(self):
        f = FALLS(2, 4, 10, 3)  # [2,4], [12,14], [22,24]
        assert falls_indices(f).tolist() == [2, 3, 4, 12, 13, 14, 22, 23, 24]

    def test_invalid(self):
        with pytest.raises(ValueError):
            FALLS(0, 5, 3, 2)  # overlapping segments
        with pytest.raises(ValueError):
            FALLS(5, 3, 10, 1)  # end < start

    def test_intersect_disjoint(self):
        a = FALLS(0, 1, 4, 5)
        b = FALLS(2, 3, 4, 5)
        assert falls_intersect(a, b) == []

    def test_intersect_identical(self):
        a = FALLS(0, 2, 5, 7)
        got = falls_list_indices(falls_intersect(a, a))
        np.testing.assert_array_equal(got, falls_indices(a))


@st.composite
def falls_strategy(draw):
    seg = draw(st.integers(1, 8))
    s = draw(st.integers(seg, 24))
    l = draw(st.integers(0, 40))
    n = draw(st.integers(1, 12))
    return FALLS(l, l + seg - 1, s, n)


class TestIntersectProperty:
    @settings(max_examples=300, deadline=None)
    @given(falls_strategy(), falls_strategy())
    def test_matches_explicit(self, f1, f2):
        got = falls_list_intersect([f1], [f2])
        want = explicit(f1) & explicit(f2)
        have = set(falls_list_indices(got).tolist())
        assert have == want
        # result FALLS must be mutually disjoint
        total = sum(len(explicit(g)) for g in got)
        assert total == len(have)

    @settings(max_examples=150, deadline=None)
    @given(falls_strategy(), falls_strategy())
    def test_commutes(self, f1, f2):
        a = set(falls_list_indices(falls_list_intersect([f1], [f2])).tolist())
        b = set(falls_list_indices(falls_list_intersect([f2], [f1])).tolist())
        assert a == b


class TestDistributions:
    def test_enhanced_block_16_over_5(self):
        """Paper Fig. 5: 16 elements over 5 ranks -> 4,3,3,3,3 (no starved rank)."""
        sizes = [falls_list_size(block_falls(16, 5, r)) for r in range(5)]
        assert sizes == [4, 3, 3, 3, 3]
        # naive ceil-blocking would have produced 4,4,4,4,0
        all_idx = np.concatenate(
            [falls_list_indices(block_falls(16, 5, r)) for r in range(5)]
        )
        np.testing.assert_array_equal(np.sort(all_idx), np.arange(16))

    @settings(max_examples=200, deadline=None)
    @given(st.integers(1, 200), st.integers(1, 16))
    def test_block_partition(self, n, p):
        """Enhanced block is a partition with fair (floor/ceil) shares."""
        chunks = [block_falls(n, p, r) for r in range(p)]
        sizes = [falls_list_size(c) for c in chunks]
        assert sum(sizes) == n
        assert max(sizes) - min(sizes) <= 1
        # contiguous and ordered
        idx = np.concatenate(
            [falls_list_indices(c) for c in chunks if c]
        )
        np.testing.assert_array_equal(idx, np.arange(n))

    @settings(max_examples=200, deadline=None)
    @given(st.integers(1, 200), st.integers(1, 16))
    def test_cyclic_partition(self, n, p):
        owned = [set(falls_list_indices(cyclic_falls(n, p, r)).tolist()) for r in range(p)]
        union = set().union(*owned)
        assert union == set(range(n))
        assert sum(len(o) for o in owned) == n
        for r in range(p):
            assert all(i % p == r for i in owned[r])

    @settings(max_examples=200, deadline=None)
    @given(st.integers(1, 300), st.integers(1, 8), st.integers(1, 9))
    def test_block_cyclic_partition(self, n, p, b):
        owned = [
            set(falls_list_indices(block_cyclic_falls(n, p, r, b)).tolist())
            for r in range(p)
        ]
        assert set().union(*owned) == set(range(n))
        assert sum(len(o) for o in owned) == n
        for r in range(p):
            assert all((i // b) % p == r for i in owned[r])

    def test_block_cyclic_truncated_tail(self):
        # n=10, p=2, b=4: rank0 blocks [0-3],[8-9](truncated); rank1 [4-7]
        r0 = falls_list_indices(block_cyclic_falls(10, 2, 0, 4)).tolist()
        r1 = falls_list_indices(block_cyclic_falls(10, 2, 1, 4)).tolist()
        assert r0 == [0, 1, 2, 3, 8, 9]
        assert r1 == [4, 5, 6, 7]


DIST_SPECS = ["b", "c", {"dist": "bc", "size": 2}, {"dist": "bc", "size": 5}, {}]


class TestRedistributionSchedule:
    @settings(max_examples=150, deadline=None)
    @given(
        st.integers(1, 120),
        st.integers(1, 6),
        st.integers(1, 6),
        st.sampled_from(DIST_SPECS),
        st.sampled_from(DIST_SPECS),
    )
    def test_schedule_covers_everything(self, n, p_src, p_dst, d_src, d_dst):
        """Every destination index is received exactly once, from the rank
        PITFALLS says owns it at the source."""
        recv_count = np.zeros(n, dtype=int)
        for dr in range(p_dst):
            want = set(
                falls_list_indices(dist_falls(n, p_dst, dr, d_dst)).tolist()
            )
            got = set()
            for sr in range(p_src):
                seg = intersect_ranks(n, p_src, d_src, p_dst, d_dst, sr, dr)
                idx = falls_list_indices(seg).tolist()
                src_owned = set(
                    falls_list_indices(dist_falls(n, p_src, sr, d_src)).tolist()
                )
                assert set(idx) <= src_owned
                assert not (set(idx) & got), "index received twice"
                got |= set(idx)
                for i in idx:
                    recv_count[i] += 1
            assert got == want
        np.testing.assert_array_equal(recv_count, np.ones(n, dtype=int))
