"""File-based PythonMPI + pRUN: the paper's transport, on real processes."""

import os
import pickle
import time
from pathlib import Path

import numpy as np
import pytest

from repro.comm import FileMPI, StragglerTimeout
from repro.launch import pRUN


class TestFileMPIUnit:
    """Single-process unit tests: self-addressed mailboxes on disk."""

    def test_send_recv_self(self, tmp_path):
        ctx = FileMPI(np_=2, pid=0, comm_dir=tmp_path, heartbeat=False)
        ctx.send(0, "t", np.arange(5))
        assert ctx.probe(0, "t")
        got = ctx.recv(0, "t")
        np.testing.assert_array_equal(got, np.arange(5))
        assert not ctx.probe(0, "t")

    def test_fifo_per_tag(self, tmp_path):
        ctx = FileMPI(np_=1, pid=0, comm_dir=tmp_path, heartbeat=False)
        for i in range(5):
            ctx.send(0, "seq", i)
        assert [ctx.recv(0, "seq") for _ in range(5)] == list(range(5))

    def test_one_sided_inspectable(self, tmp_path):
        """Sends post without a receiver and sit on disk, inspectable —
        the paper's debugging affordance (§III.D)."""
        ctx = FileMPI(np_=2, pid=0, comm_dir=tmp_path, heartbeat=False)
        ctx.send(1, "dbg", {"x": 42})
        bufs = list(Path(tmp_path).glob("m_s0_d1_*.buf"))
        assert len(bufs) == 1
        with open(bufs[0], "rb") as f:
            assert pickle.load(f) == {"x": 42}

    def test_recv_timeout_raises_straggler(self, tmp_path):
        ctx = FileMPI(np_=2, pid=0, comm_dir=tmp_path, heartbeat=False)
        t0 = time.monotonic()
        with pytest.raises(StragglerTimeout):
            ctx.recv(1, "never", timeout=0.2)
        assert time.monotonic() - t0 < 5

    def test_arbitrary_tags(self, tmp_path):
        ctx = FileMPI(np_=1, pid=0, comm_dir=tmp_path, heartbeat=False)
        tag = ("redist", 3, "dim0")
        ctx.send(0, tag, "payload")
        assert ctx.recv(0, tag) == "payload"

    def test_heartbeat_and_dead_rank_detection(self, tmp_path):
        a = FileMPI(np_=2, pid=0, comm_dir=tmp_path)
        # rank 1 never starts -> immediately reported dead (missing file)
        assert a.dead_ranks(max_age=0.5) == [1]
        b = FileMPI(np_=2, pid=1, comm_dir=tmp_path)
        assert a.dead_ranks(max_age=10.0) == []
        a.finalize()
        b.finalize()


@pytest.mark.slow
class TestPRunProcesses:
    """Real multi-process SPMD through the shared filesystem."""

    def test_pingpong(self):
        res = pRUN("repro.launch._selftest:pingpong", 2, timeout=120)
        want = (np.arange(1000.0).sum()) * 2
        assert res[0] == want

    def test_bcast_barrier(self):
        res = pRUN("repro.launch._selftest:bcast_barrier", 3, timeout=120)
        assert res == [7.0 * 64] * 3

    def test_redistribute_across_processes(self):
        res = pRUN("repro.launch._selftest:redistribute_field", 3, timeout=180)
        want = np.arange(90.0).reshape(9, 10)
        np.testing.assert_array_equal(np.array(res[0]), want)
        assert res[1] is None and res[2] is None

    def test_complex_round_trip(self):
        res = pRUN("repro.launch._selftest:complex_messages", 2, timeout=120)
        assert all(res)


class TestSlurmInterface:
    def test_script_render(self, tmp_path):
        from repro.launch.slurm import slurm_script, submit

        txt = slurm_script(
            "repro.launch._selftest:pingpong", 64, "/shared/comm",
            partition="xeon-p8", nodes=2,
        )
        assert "#SBATCH --ntasks=64" in txt
        assert "PPYTHON_COMM_DIR=/shared/comm" in txt
        assert "OMP_NUM_THREADS=1" in txt  # paper §III.F.4
        assert "PPYTHON_PID=\\$SLURM_PROCID" in txt
        # no sbatch on this host -> returns script path
        out = submit(txt, tmp_path)
        assert out.endswith(".sbatch") and os.path.exists(out)
