"""Repo-root conftest: makes ``benchmarks/`` importable from tests
regardless of how pytest is invoked (``pytest tests/`` vs ``python -m
pytest``).  Does NOT touch XLA flags — only the dry-run entry point may
pin the device count (see repro/launch/dryrun.py)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
